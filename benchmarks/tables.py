"""Benchmark implementations, one per paper table (I-V).

Each function returns a list of CSV rows (dicts); benchmarks/run.py prints
them.  CPU wall-times here stand in for the paper's Xeon cycle counts; the
TPU-side story lives in experiments/roofline (§Roofline of EXPERIMENTS.md).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, repeats=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _electron_positions(sys, n=None, seed=0):
    rng = np.random.default_rng(seed)
    n = n or sys.mol.n_elec
    at = rng.integers(0, sys.mol.coords.shape[0], n)
    return jnp.asarray(sys.mol.coords[at]
                       + rng.normal(scale=1.2, size=(n, 3)), jnp.float32)


# ---------------------------------------------------------------------------
# Table I: performance of the MO products (dense vs sparse vs kernel)
# ---------------------------------------------------------------------------
def table1(quick=True):
    from repro.core import aos, mos
    from repro.kernels.sparse_mo.ops import sparse_mo_products
    from repro.systems.bench import paper_system

    systems = ['smallest', 'b-strand'] + ([] if quick else
                                          ['b-strand-tz', '1ze7', '1amb'])
    rows = []
    for name in systems:
        s = paper_system(name)
        A = jnp.asarray(s.mos)
        r = _electron_positions(s)
        B, atom_active = aos.eval_ao_block(
            s.basis, jnp.asarray(s.mol.coords, jnp.float32), r)
        mask = atom_active[:, jnp.asarray(s.basis.ao_atom)]
        nnz = float(jnp.mean(mask))
        n_orb, n_ao = A.shape
        n_e = r.shape[0]
        dense_flops = 2 * n_orb * n_ao * n_e * 5
        sparse_flops = dense_flops * nnz

        t_dense = _timeit(jax.jit(mos.mo_products_dense), A, B)
        idx, valid, _ = aos.active_ao_indices(s.basis, atom_active, 512)
        Bp = aos.pack_b(B, idx, valid)
        t_sparse = _timeit(
            jax.jit(lambda a, bp, ix: mos.mo_products_sparse(a, bp, ix)),
            A, Bp, idx)
        rows.append(dict(table='I', system=name, method='dense',
                         time_s=round(t_dense, 4),
                         gflops=round(dense_flops / t_dense / 1e9, 2)))
        rows.append(dict(table='I', system=name, method='sparse-AO',
                         time_s=round(t_sparse, 4),
                         gflops=round(sparse_flops / t_sparse / 1e9, 2),
                         speedup=round(t_dense / t_sparse, 2),
                         b_density=round(nnz, 3)))
        if quick and name == 'smallest':   # kernel interpret mode is slow
            t_kern = _timeit(
                jax.jit(lambda a, b, m: sparse_mo_products(
                    a, b, m, tile_o=32, tile_k=32, tile_e=8)),
                A, B, mask)
            rows.append(dict(table='I', system=name, method='pallas-kernel',
                             time_s=round(t_kern, 4),
                             note='interpret=True (CPU validation mode)'))
    return rows


# ---------------------------------------------------------------------------
# Table II: per-QMC-step cost breakdown + memory footprint
# ---------------------------------------------------------------------------
def table2(quick=True):
    from repro.core import aos, mos, slater
    from repro.systems.bench import paper_system

    systems = ['smallest', 'b-strand'] + ([] if quick else
                                          ['b-strand-tz', '1ze7', '1amb'])
    rows = []
    for name in systems:
        s = paper_system(name)
        A = jnp.asarray(s.mos)
        r = _electron_positions(s)
        B, _ = aos.eval_ao_block(
            s.basis, jnp.asarray(s.mol.coords, jnp.float32), r)
        n_up = s.mol.n_up

        eval_ao = jax.jit(lambda rr: aos.eval_ao_block(
            s.basis, jnp.asarray(s.mol.coords, jnp.float32), rr)[0])
        prod = jax.jit(mos.mo_products_dense)
        inv = jax.jit(lambda C: jnp.linalg.inv(C[:n_up, :n_up, 0]))

        t_ao = _timeit(eval_ao, r)
        C = prod(A, B)
        t_prod = _timeit(prod, A, B)
        t_inv = _timeit(inv, C)
        total = t_ao + t_prod + t_inv
        # memory footprint: parameters + one walker's work set
        mem = (A.size * 4 + B.size * 4 + C.size * 4
               + 2 * n_up * n_up * 4) / 2 ** 20
        rows.append(dict(
            table='II', system=name, n_elec=s.mol.n_elec,
            step_s=round(total, 4), ao_pct=round(100 * t_ao / total, 1),
            products_pct=round(100 * t_prod / total, 1),
            inversion_pct=round(100 * t_inv / total, 1),
            ram_mib=round(mem, 1)))
    return rows


# ---------------------------------------------------------------------------
# Table III: spline interpolation vs direct computation
# ---------------------------------------------------------------------------
def table3(quick=True):
    from repro.core import aos, mos, spline
    from repro.systems.molecule import build_wavefunction, water
    from repro.systems.bench import paper_system, build_bench_wavefunction

    rows = []
    # water (exact MOs) + smallest bench system
    mol, shells = water()
    cfg, params = build_wavefunction(mol, shells, method='dense')
    grid = spline.build_mo_grid(cfg.basis, params.coords, params.mo,
                                (40, 40, 40))
    r = jax.random.normal(jax.random.PRNGKey(0), (mol.n_elec, 3)) * 1.2
    interp = jax.jit(lambda rr: spline.interp_mo_block(grid, rr))
    direct = jax.jit(lambda rr: mos.mo_products_dense(
        params.mo, aos.eval_ao_block(cfg.basis, params.coords, rr)[0]))
    t_i = _timeit(interp, r)
    t_d = _timeit(direct, r)
    rows.append(dict(table='III', system='water', direct_s=round(t_d, 5),
                     spline_s=round(t_i, 5),
                     ratio=round(t_d / t_i, 2),
                     spline_mem_mib=round(grid.memory_bytes / 2 ** 20, 1),
                     direct_mem_mib=round(params.mo.size * 4 / 2 ** 20, 2)))
    if not quick:
        s = paper_system('smallest')
        cfgb, pb = build_bench_wavefunction(s, method='dense')
        grid_b = spline.build_mo_grid(s.basis, pb.coords, pb.mo,
                                      (48, 48, 48))
        rb = _electron_positions(s)
        interp_b = jax.jit(lambda rr: spline.interp_mo_block(grid_b, rr))
        direct_b = jax.jit(lambda rr: mos.mo_products_dense(
            pb.mo, aos.eval_ao_block(s.basis, pb.coords, rr)[0]))
        t_ib = _timeit(interp_b, rb)
        t_db = _timeit(direct_b, rb)
        rows.append(dict(table='III', system='smallest',
                         direct_s=round(t_db, 5), spline_s=round(t_ib, 5),
                         ratio=round(t_db / t_ib, 2),
                         spline_mem_mib=round(grid_b.memory_bytes / 2 ** 20,
                                              1)))
    return rows


# ---------------------------------------------------------------------------
# Table IV: sparsity of A (MO coeffs) and B (AO values)
# ---------------------------------------------------------------------------
def table4(quick=True):
    from repro.core import aos
    from repro.systems.bench import paper_system

    paper_vals = {'smallest': (81.3, 36.2, 146), 'b-strand': (48.4, 14.8,
                                                              142),
                  'b-strand-tz': (73.4, 8.2, 241), '1ze7': (49.4, 5.7, 135),
                  '1amb': (37.1, 3.9, 152)}
    systems = list(paper_vals) if not quick else ['smallest', 'b-strand',
                                                  '1ze7']
    rows = []
    for name in systems:
        s = paper_system(name)
        r = _electron_positions(s)
        _, atom_active = aos.eval_ao_block(
            s.basis, jnp.asarray(s.mol.coords, jnp.float32), r)
        mask = atom_active[:, jnp.asarray(s.basis.ao_atom)]
        counts = np.asarray(jnp.sum(mask, 1))
        pa, pb, pk = paper_vals[name]
        rows.append(dict(
            table='IV', system=name, n_elec=s.mol.n_elec,
            n_basis=s.basis.n_ao,
            a_nonzero_pct=round(100 * s.a_density, 1),
            paper_a_pct=pa,
            b_nonzero_pct=round(100 * float(jnp.mean(mask)), 1),
            paper_b_pct=pb,
            avg_active_ao=int(counts.mean()), paper_k=pk,
            max_active_ao=int(counts.max())))
    return rows


# ---------------------------------------------------------------------------
# Table V: parallel speed-up of the block runtime (forwarder tree)
# ---------------------------------------------------------------------------
def table5(quick=True):
    import repro.runtime as rt
    from tests.test_runtime import FakeSampler

    duration = 1.5 if quick else 4.0
    counts = [1, 2, 4, 8] if quick else [1, 2, 4, 8, 16, 32]
    base = None
    rows = []
    for n in counts:
        ctl = rt.RunControl(wall_clock_limit=duration,
                            poll_interval=0.05, subblocks_per_block=2)
        # sleep-bound fake sampler: models the GIL-free XLA compute of a
        # real worker so thread-level scaling is measurable on one core
        mgr = rt.QMCManager(FakeSampler(delay=0.01), f'tab5-{n}', ctl,
                            backend=rt.ThreadBackend(n))
        t0 = time.monotonic()
        avg = mgr.run()
        wall = time.monotonic() - t0
        rate = avg.n_blocks / wall
        if base is None:
            base = rate
        rows.append(dict(table='V', workers=n,
                         blocks=avg.n_blocks,
                         blocks_per_s=round(rate, 1),
                         speedup=round(rate / base, 2),
                         efficiency=round(rate / base / n, 3)))
    return rows


# ---------------------------------------------------------------------------
# Table IX: parallel efficiency of the runtime backends (thread vs process)
# ---------------------------------------------------------------------------
def table_runtime(quick=True):
    """Paper Table IV/V-style parallel efficiency of the block runtime.

    Block throughput vs worker count for the thread and process execution
    substrates (same manager, same forwarder tree, same block target).
    The rate is *steady-state*: computed from the stored block timestamps
    (first to last), so process-spawn cold start — interpreter boot +
    sampler unpickle — is excluded.  ``speedup``/``efficiency`` are
    relative to each backend's own 1-worker rate; ``vs_thread`` compares
    the substrates at equal worker count (the process backend pays
    pickling + queue hops — the paper's compressed-transfer path).
    """
    from benchmarks.samplers import RuntimeBenchSampler
    from repro.runtime import QMCManager, RunControl, make_backend

    per_worker_blocks = 20 if quick else 60
    counts = [1, 2, 4] if quick else [1, 2, 4, 8, 16]
    rows = []
    thread_rates = {}
    for backend_name in ('thread', 'process'):
        base = None
        for n in counts:
            target = per_worker_blocks * n
            ctl = RunControl(max_blocks=target, poll_interval=0.05,
                             subblocks_per_block=2)
            mgr = QMCManager(RuntimeBenchSampler(delay=0.01),
                             f'tab9-{backend_name}-{n}', ctl,
                             backend=make_backend(backend_name, n))
            avg = mgr.run()
            ts = sorted(b.timestamp
                        for b in mgr.db.blocks(f'tab9-{backend_name}-{n}'))
            span = ts[-1] - ts[0]
            rate = (len(ts) - 1) / span if span > 0 else float('nan')
            if base is None:
                base = rate
            if backend_name == 'thread':
                thread_rates[n] = rate
            row = dict(table='IX', backend=backend_name, workers=n,
                       blocks=avg.n_blocks, blocks_per_s=round(rate, 1),
                       speedup=round(rate / base, 2),
                       efficiency=round(rate / base / n, 3))
            if backend_name == 'process' and thread_rates.get(n):
                row['vs_thread'] = round(rate / thread_rates[n], 2)
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Table XI: parallel efficiency of the TCP grid backend vs thread/process
# ---------------------------------------------------------------------------
def table_grid(quick=True):
    """Localhost-grid parallel efficiency (paper's multi-host deployment).

    The same fixed-cost sampler workload on three substrates — in-process
    threads, OS processes, and the TCP ``GridBackend`` with real
    ``qmc_worker`` subprocess workers (heartbeats + binary packets over
    sockets).  Rates are steady-state (from stored block timestamps, so
    subprocess boot is excluded); ``efficiency`` is relative to each
    backend's own 1-worker rate and ``vs_thread`` compares substrates at
    equal worker count — the gap is the full wire-protocol cost
    (encode + TCP + CRC + decode per block packet).
    """
    from benchmarks.samplers import RuntimeBenchSampler
    from repro.runtime import (GridBackend, GridConfig, QMCManager,
                               RunControl, make_backend)

    delay = 0.01
    per_worker_blocks = 20 if quick else 50
    counts = [1, 2, 4] if quick else [1, 2, 4, 8]
    rows = []
    thread_rates = {}
    for backend_name in ('thread', 'process', 'grid'):
        base = None
        for n in counts:
            key = f'tab11-{backend_name}-{n}'
            ctl = RunControl(max_blocks=per_worker_blocks * n,
                             poll_interval=0.05, subblocks_per_block=2)
            if backend_name == 'grid':
                # workers are real qmc_worker subprocesses building the
                # same gauss sampler locally from CLI flags
                backend = GridBackend(n, net=GridConfig(worker_args=(
                    '--sampler', f'gauss:delay={delay}')))
            else:
                backend = make_backend(backend_name, n)
            mgr = QMCManager(RuntimeBenchSampler(delay=delay), key, ctl,
                             backend=backend)
            avg = mgr.run()
            ts = sorted(b.timestamp for b in mgr.db.blocks(key))
            span = ts[-1] - ts[0]
            rate = (len(ts) - 1) / span if span > 0 else float('nan')
            if base is None:
                base = rate
            if backend_name == 'thread':
                thread_rates[n] = rate
            row = dict(table='XI', backend=backend_name, workers=n,
                       blocks=avg.n_blocks, blocks_per_s=round(rate, 1),
                       speedup=round(rate / base, 2),
                       efficiency=round(rate / base / n, 3))
            if backend_name != 'thread' and thread_rates.get(n):
                row['vs_thread'] = round(rate / thread_rates[n], 2)
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Table VI: ensemble-flattened vs per-walker-vmap psi evaluation
# ---------------------------------------------------------------------------
def table_ensemble(quick=True):
    """Per-walker ``vmap(psi_state)`` vs the fused ``psi_state_batched``.

    Table-III-style ratio rows, one per (method, W): same configuration,
    same random walkers, both paths jitted, min-of-5 wall time.  The
    ensemble path is the paper's load-amortization/cache-blocking idea
    scaled to the walker population (DESIGN.md §4).
    """
    import dataclasses
    from functools import partial

    from repro.core.wavefunction import psi_state, psi_state_batched
    from repro.systems.bench import build_bench_wavefunction, \
        make_bench_system

    s = make_bench_system('micro-peptide', n_elec=60, seed=5)
    n_e = s.mol.n_elec
    walker_counts = [16, 64] if quick else [16, 64, 256]
    rng = np.random.default_rng(0)

    rows = []
    for method in ('dense', 'sparse', 'kernel'):
        cfg, params = build_bench_wavefunction(s, method=method, k_max=160)
        # per-walker tiles sized to one walker's 60 electrons; the ensemble
        # path widens tile_e itself (ensemble_tile_e)
        cfg = dataclasses.replace(cfg, kernel_tiles=(16, 32, 8))
        for W in walker_counts:
            if method == 'kernel' and W > 64:
                continue                 # interpret-mode cost cap
            at = rng.integers(0, s.mol.coords.shape[0], (W, n_e))
            R = jnp.asarray(s.mol.coords[at]
                            + rng.normal(scale=1.2, size=(W, n_e, 3)),
                            jnp.float32)
            f_vmap = jax.jit(
                lambda p, RR, c=cfg: jax.vmap(partial(psi_state, c, p))(RR))
            f_ens = jax.jit(lambda p, RR, c=cfg: psi_state_batched(c, p, RR))
            t_v = _timeit(f_vmap, params, R, repeats=5)
            t_e = _timeit(f_ens, params, R, repeats=5)
            rows.append(dict(
                table='VI', system=s.name, method=method, walkers=W,
                n_elec=n_e, vmap_s=round(t_v, 4), ensemble_s=round(t_e, 4),
                speedup=round(t_v / t_e, 2)))
    return rows


# ---------------------------------------------------------------------------
# Table VIII: single-electron moves vs all-electron recompute
# ---------------------------------------------------------------------------
def table_sem(quick=True):
    """Per-sweep cost of the Sherman–Morrison single-electron propagator.

    For each bench system three jitted measurements at the same W:

    * ``sem_sweep_s``      — one ``SEMVMCPropagator.propagate`` call:
      n_e single-electron trials (AO values + dot + batched rank-1 update)
      plus ONE full MO-tensor pass for the energy, zero factorizations;
    * ``recompute_sweep_s`` — what the same sweep costs when every move
      pays a full recompute (the paper's baseline): n_e x one
      all-electron evaluation (AO + MO products + batched slogdet/inv);
    * ``allelec_step_s``    — one all-electron ``VMCPropagator.propagate``
      generation, for context (it moves all electrons in ONE trial, a
      different kinetics with lower acceptance at large n_e).

    ``speedup`` = recompute_sweep_s / sem_sweep_s: how much the maintained
    inverse saves per sweep.  Grows with n_e (the paper's scaling story).
    """
    from repro.core.driver import Population
    from repro.core.sem import SEMVMCPropagator
    from repro.core.vmc import VMCPropagator, evaluate_ensemble
    from repro.systems.bench import build_bench_wavefunction, \
        make_bench_system

    sizes = [30, 60] if quick else [30, 60, 120, 240]
    W = 8
    pop = Population()
    rows = []
    for n_elec in sizes:
        s = make_bench_system('micro-peptide', n_elec=n_elec, seed=5)
        cfg, params = build_bench_wavefunction(s, method='sparse', k_max=160)
        n_e = s.mol.n_elec

        sem = SEMVMCPropagator(cfg, step_size=0.4)
        state = sem.init(params, jax.random.PRNGKey(0), W)
        f_sem = jax.jit(lambda p, st, k: sem.propagate(p, st, k, pop))
        t_sem = _timeit(f_sem, params, state, jax.random.PRNGKey(1))

        vmc = VMCPropagator(cfg, tau=0.3)
        ens = vmc.init(params, jax.random.PRNGKey(0), W)
        f_vmc = jax.jit(lambda p, st, k: vmc.propagate(p, st, k, pop))
        t_vmc = _timeit(f_vmc, params, ens, jax.random.PRNGKey(1))

        # full-recompute baseline: one all-electron evaluation (the cost a
        # naive single-electron sweep pays PER MOVE), times n_e moves
        f_eval = jax.jit(lambda p, r: evaluate_ensemble(cfg, p, r)[0])
        t_eval = _timeit(f_eval, params, ens.r)
        rows.append(dict(
            table='VIII', system=s.name, n_elec=n_e, walkers=W,
            sem_sweep_s=round(t_sem, 4),
            sem_move_us=round(1e6 * t_sem / n_e, 1),
            recompute_sweep_s=round(n_e * t_eval, 4),
            allelec_step_s=round(t_vmc, 4),
            speedup=round(n_e * t_eval / t_sem, 2)))
    return rows


# ---------------------------------------------------------------------------
# Table X: multideterminant ratios — shared inverse vs per-determinant slogdet
# ---------------------------------------------------------------------------
def table_multidet(quick=True):
    """Ratio-evaluation cost of a CI expansion vs its size n_det.

    For one walker ensemble (W = 64) of a 60-electron bench system, both
    jitted paths evaluate det(D_I)/det(D_ref) for ALL determinants:

    * ``shared_s`` — the shared-inverse SMW path (``core.multidet``): ONE
      batched inverse of the reference per spin, one GEMM for the table
      P = V @ M, then a gathered k×k determinant per excitation;
    * ``naive_s`` — per-determinant slogdet: materialize every excited
      Slater matrix by hole->particle row substitution and factorize it
      (batched LAPACK, still O(n_det n^3) flops — the cost model the
      multideterminant papers start from).

    ``speedup`` = naive_s / shared_s.  The shared path's cost is dominated
    by the n_det-INDEPENDENT factorization+table, so the speedup grows
    linearly with n_det (paper-scale expansions: thousands).
    """
    import dataclasses

    from repro.core import multidet
    from repro.core.wavefunction import _ci_blocks, _mo_tensor_ensemble
    from repro.systems.bench import build_bench_wavefunction, \
        make_bench_system

    W = 64                               # acceptance point: n_det=100, W=64
    sizes = [1, 10, 100] if quick else [1, 10, 100, 1000]
    s = make_bench_system('micro-peptide', n_elec=60, seed=5)
    n_up, n_dn = s.mol.n_up, s.mol.n_dn
    rng = np.random.default_rng(0)
    rows = []
    for n_det in sizes:
        cfg, params = build_bench_wavefunction(s, method='dense',
                                               n_det=max(n_det, 2))
        ci = (cfg.ci if n_det > 1 else multidet.from_excitations(
            [1.0], [], n_up, n_dn, cfg.ci.n_orb))
        cfg = dataclasses.replace(cfg, ci=ci)
        at = rng.integers(0, s.mol.coords.shape[0], (W, cfg.n_elec))
        R = jnp.asarray(s.mol.coords[at]
                        + rng.normal(scale=1.2, size=(W, cfg.n_elec, 3)),
                        jnp.float32)
        Cw, _ = _mo_tensor_ensemble(cfg, params, R)
        up_all, dn_all = _ci_blocks(cfg, Cw)
        V_up, V_dn = up_all[..., 0], dn_all[..., 0]   # (W, n_orb, n_spin)

        def shared(Vu, Vd, ci=ci):
            Mu = jnp.linalg.inv(Vu[..., :n_up, :])
            Md = jnp.linalg.inv(Vd[..., :n_dn, :])
            ru = multidet.det_ratios(multidet.reference_table(Vu, Mu),
                                     ci.holes_up, ci.parts_up)
            rd = multidet.det_ratios(multidet.reference_table(Vd, Md),
                                     ci.holes_dn, ci.parts_dn)
            return ru * rd

        def naive(Vu, Vd, ci=ci):
            def spin(V, holes, parts, n_occ):
                # (n_det, n_occ) row map: hole slots swapped to particles
                k = holes.shape[1]
                rows_idx = jnp.broadcast_to(jnp.arange(n_occ),
                                            (ci.n_det, n_occ))
                for a in range(k):
                    real = holes[:, a] < n_occ   # sentinel = pad slot
                    rows_idx = jnp.where(
                        (jnp.arange(n_occ)[None, :] == holes[:, a, None])
                        & real[:, None], parts[:, a, None], rows_idx)
                ext = multidet._pad_zero_rows(V, -2, k)
                D_I = ext[..., rows_idx, :]      # (W, n_det, n_occ, n_occ)
                sI, lI = jnp.linalg.slogdet(D_I)
                s0, l0 = jnp.linalg.slogdet(V[..., :n_occ, :])
                return sI * s0[..., None] * jnp.exp(lI - l0[..., None])
            ru = spin(Vu, jnp.asarray(ci.holes_up),
                      jnp.asarray(ci.parts_up), n_up)
            rd = spin(Vd, jnp.asarray(ci.holes_dn),
                      jnp.asarray(ci.parts_dn), n_dn)
            return ru * rd

        f_shared = jax.jit(shared)
        f_naive = jax.jit(naive)
        t_shared = _timeit(f_shared, V_up, V_dn)
        t_naive = _timeit(f_naive, V_up, V_dn)
        a, b = f_shared(V_up, V_dn), f_naive(V_up, V_dn)
        # f32 parity, relative to the ratio scale (both paths share the
        # reference factorization's conditioning)
        rel = float(jnp.max(jnp.abs(a - b)) / jnp.maximum(
            jnp.max(jnp.abs(b)), 1.0))
        rows.append(dict(
            table='X', system=s.name, n_elec=cfg.n_elec, walkers=W,
            n_det=n_det, shared_s=round(t_shared, 5),
            naive_s=round(t_naive, 5),
            speedup=round(t_naive / t_shared, 2),
            rel_err=round(rel, 6)))
    return rows


# ---------------------------------------------------------------------------
# Table VII: unified-driver block throughput (single-device vs walker mesh)
# ---------------------------------------------------------------------------
def table_driver(quick=True):
    """One jit'd block through ``EnsembleDriver`` for each Propagator.

    Rows report walker-generations/second for VMC and DMC at growing W,
    plus a ``shards`` column: with >1 local device (e.g. under
    XLA_FLAGS=--xla_force_host_platform_device_count=8) the same block is
    also run with the walker axis sharded over the ``walkers`` mesh — same
    trajectories (per-walker RNG), so the ratio is pure scaling overhead.
    """
    import warnings

    from repro.core.dmc import DMCPropagator
    from repro.core.driver import EnsembleDriver
    from repro.core.vmc import VMCPropagator
    from repro.sharding import walkers_mesh
    from repro.systems.molecule import build_wavefunction, h2

    cfg, params = build_wavefunction(*h2())
    steps = 20 if quick else 50
    walker_counts = [64, 256] if quick else [64, 256, 1024]
    n_dev = len(jax.local_devices())
    meshes = [(1, None)] + ([(n_dev, walkers_mesh())] if n_dev > 1 else [])

    rows = []
    for method, prop in [('vmc', VMCPropagator(cfg, tau=0.3)),
                         ('dmc', DMCPropagator(cfg, e_trial=-1.17,
                                               tau=0.02))]:
        for W in walker_counts:
            for shards, mesh in meshes:
                if W % max(shards, 1):
                    continue
                drv = EnsembleDriver(prop, steps, mesh=mesh, donate=False)
                with warnings.catch_warnings():
                    warnings.simplefilter('ignore')
                    state = drv.init(params, jax.random.PRNGKey(0), W)
                key = jax.random.PRNGKey(1)
                t = _timeit(lambda: drv.run_block(params, state, key),
                            repeats=3)
                rows.append(dict(
                    table='VII', system='h2', method=method, walkers=W,
                    steps=steps, shards=shards, block_s=round(t, 4),
                    walker_steps_per_s=int(W * steps / t)))
    return rows


# ---------------------------------------------------------------------------
# Table XII: wavefunction-optimization trajectory + moment-accumulation cost
# ---------------------------------------------------------------------------
def table_opt(quick=True):
    """`opt-vmc` end price: descent trajectory and per-step overhead.

    Two measurements on the synthetic-CI water system (DESIGN.md §10):

    * the energy/variance trajectory of a seeded SR optimization over
      ``opt_steps`` parameter updates at n_det = 1 and 100 (the full
      runtime loop: thread workers, version-stamped blocks, broadcast) —
      each row is one optimization step;
    * ``mode=overhead`` rows: wall time of one jitted sub-block under the
      ``opt-vmc`` propagator (VMC sampling + the four O-moment
      accumulations, P = 3 + n_det parameters) vs the plain ``vmc``
      propagator on identical settings — the pure price of gradient
      accumulation, compile excluded.
    """
    from repro.core.driver import make_propagator
    from repro.launch.spec import RunSpec, build_run
    from repro.runtime.samplers import BlockSampler
    from repro.systems import build_system

    sizes = [1, 100]
    opt_steps = 3 if quick else 6
    rows = []
    for n_det in sizes:
        # tau sized for water's O core (the 0.3 method default freezes
        # Metropolis at Z=8); heavy damping because at P = 103 the
        # overlap matrix is estimated from a handful of small blocks
        spec = RunSpec(system='water', method='opt-vmc', n_det=n_det,
                       tau=0.02, backend='thread', n_workers=2,
                       n_walkers=16, steps=30, subblocks_per_block=2,
                       opt_steps=opt_steps, opt_blocks_per_step=4,
                       opt_lr=0.05, sr_damping=0.5, seed=0)
        run = build_run(spec)
        res = run.run()
        for s in res.steps:
            rows.append(dict(
                table='XII', system='water', n_det=n_det, mode='trajectory',
                step=s.step, energy=round(s.energy, 5),
                error=round(s.error, 5), variance=round(s.variance, 4),
                blocks=s.n_blocks))
    for n_det in sizes:
        cfg, params = build_system('water', n_det=n_det, ci_seed=0)
        times = {}
        for method in ('vmc', 'opt-vmc'):
            prop = make_propagator(method, cfg, tau=0.3, e_trial=None,
                                   equil_steps=0)
            samp = BlockSampler(prop, params, n_walkers=8, steps=5)
            # the jitted block donates its state buffer: advance the
            # held state every call instead of reusing a dead buffer
            hold = {'state': samp.init_state(0, seed=0), 'step': 0}

            def tick(s=samp, h=hold):
                h['state'], acc, _, _ = s.run_subblock(h['state'],
                                                       h['step'])
                h['step'] += 1
                return acc.weight
            times[method] = _timeit(tick)
        n_p = 3 + (n_det if n_det > 1 else 0)
        rows.append(dict(
            table='XII', system='water', n_det=n_det, mode='overhead',
            n_params=n_p, vmc_s=round(times['vmc'], 5),
            opt_s=round(times['opt-vmc'], 5),
            overhead=round(times['opt-vmc'] / times['vmc'], 2)))
    return rows


# ---------------------------------------------------------------------------
# Table XIII: distance-screened pipeline — wavefunction cost per SEM sweep
# ---------------------------------------------------------------------------
def table_scaling(quick=True):
    """Scaling law of wavefunction construction, screened vs dense.

    For a growing extended peptide chain (``systems.bench.synthetic_chain``,
    spanning the paper's Table IV range 158 -> 1731 electrons) time the two
    wavefunction-construction components of one single-electron-move sweep
    at W = 1 walker:

    * ``mo_pass_s`` — one full MO-tensor pass (B -> C = A @ B), the
      once-per-sweep energy/drift evaluation;
    * ``moves_s``   — a sweep's worth (n_e) of sequential per-move orbital
      evaluations (AO values at the proposed point + the phi row product),
      jitted as one ``lax.scan`` so Python dispatch stays out of the fit.

    ``sweep_s = mo_pass_s + moves_s`` deliberately EXCLUDES the
    Sherman–Morrison inverse-update algebra: that part is O(n_e^2) per
    sweep for *any* orbital-evaluation strategy (Table VIII measures it);
    this table isolates exactly the cost the paper's §II-§III screening
    attacks.  Screened rows run at eps = 0 — bitwise-identical physics to
    the dense rows (tests/test_screening.py) — so the fitted exponent gap
    is pure structure exploitation, not a tolerance trade.

    The last rows fit log-log slopes over the size series; the committed
    ``BENCH_scaling.json`` gates the ``exponent`` metric through
    ``tools/bench_gate.py`` (screened must stay sub-quadratic).
    ``*_mb`` columns are the ``screening.memory_budget`` peak-footprint
    estimates for one full pass (paper idea ii.).
    """
    from repro.core import aos
    from repro.core import screening as scr_mod
    from repro.core import wavefunction as wf
    from repro.systems.bench import build_bench_wavefunction, synthetic_chain

    sizes = [158, 434, 872] if quick else [158, 434, 872, 1056, 1731]
    rows = []
    series = {'screened': [], 'dense': []}
    for n_elec in sizes:
        s = synthetic_chain(n_elec)
        n_e = s.mol.n_elec
        r = _electron_positions(s, seed=3)
        r_prop = r + 0.3                     # a sweep's proposed positions

        for label in ('screened', 'dense'):
            cfg, params = build_bench_wavefunction(
                s, method='sparse' if label == 'screened' else 'dense',
                screen_eps=0.0 if label == 'screened' else None)
            bas, A = cfg.basis, params.mo

            f_full = jax.jit(lambda p, rr, cfg=cfg:
                             wf._mo_tensor(cfg, p, rr)[0])
            t_full = _timeit(f_full, params, r)

            if label == 'screened':
                scr = cfg.screening

                def f_moves(p, rp, cfg=cfg, scr=scr, bas=bas):
                    def body(acc, point):
                        pt = point[None]
                        idx, act, _ = scr_mod.active_ao_lists(scr, pt)
                        vals = aos.eval_ao_values_screened(
                            bas, p.coords, pt, idx, act)
                        if scr.mo_cells is not None:
                            mo_idx, mo_valid = scr_mod.active_mo_lists(
                                scr, pt)
                            phi = scr_mod.gather_phi(p.mo, idx, vals,
                                                     mo_idx, mo_valid,
                                                     chunk=1)
                        else:
                            phi = scr_mod.phi_from_packed(p.mo, idx, vals,
                                                          bas.n_ao)
                        return acc + jnp.sum(phi), None
                    out, _ = jax.lax.scan(body, jnp.float32(0), rp)
                    return out
            else:
                def f_moves(p, rp, bas=bas):
                    def body(acc, point):
                        v, _ = aos.eval_ao_values(bas, p.coords, point[None])
                        return acc + jnp.sum(p.mo @ v), None
                    out, _ = jax.lax.scan(body, jnp.float32(0), rp)
                    return out
            t_moves = _timeit(jax.jit(f_moves), params, r_prop)

            sweep = t_full + t_moves
            series[label].append((n_e, sweep))
            mb = scr_mod.memory_budget(
                cfg.screening if label == 'screened'
                else scr_mod.build_screening(bas, s.mol.coords, A, eps=-1.0),
                bas, n_e, A.shape[0])
            rows.append(dict(
                table='XIII', system=s.name, n_elec=n_e, n_ao=bas.n_ao,
                method=label,
                ao_budget=(cfg.screening.ao_budget
                           if label == 'screened' else bas.n_ao),
                mo_budget=(cfg.screening.mo_budget
                           if label == 'screened' else 0),
                mo_pass_s=round(t_full, 4), moves_s=round(t_moves, 4),
                sweep_s=round(sweep, 4),
                mem_mb=round((mb['screened_total'] if label == 'screened'
                              else mb['dense_total']) / 2**20, 1)))

    for label, pts in series.items():
        n = np.array([p[0] for p in pts], float)
        t = np.array([p[1] for p in pts], float)
        slope = float(np.polyfit(np.log(n), np.log(t), 1)[0])
        rows.append(dict(
            table='XIII', system='chain-fit', method=label,
            n_min=int(n[0]), n_max=int(n[-1]),
            exponent=round(slope, 3)))
    return rows


# ---------------------------------------------------------------------------
# Table XIV: multi-tenant service throughput (N concurrent runs vs 1)
# ---------------------------------------------------------------------------
def table_serve(quick=True):
    """Throughput cost of multi-tenancy in the ``QMCService`` engine.

    One fixed worker pool serves N concurrent tenant runs (the sleep-bound
    Gaussian sampler stands in for GIL-free XLA compute, as in Table V):
    each tenant submits the same per-run block target and the table
    reports the *aggregate* steady-state block rate.  ``vs_single`` is
    that rate relative to the N = 1 row — the whole pool behind one run —
    so it measures the pure price of fair-share scheduling, lease
    resizing, and per-run manager polling.  ``fairness`` is the
    min/max ratio of blocks landed per tenant (1.0 = perfectly even);
    the committed ``BENCH_serve.json`` gates both through
    ``tools/bench_gate.py``.
    """
    from repro.launch.spec import RunSpec
    from repro.serve import QMCService, gaussian_builder

    pool = 4
    blocks_per_run = 24 if quick else 60
    tenant_counts = [1, 2, 4] if quick else [1, 2, 4, 8]
    rows = []
    base = None
    for n_runs in tenant_counts:
        svc = QMCService(total_workers=pool, builder=gaussian_builder,
                         poll_interval=0.02)
        try:
            specs = [RunSpec(system=f'tenant{i}', method='vmc',
                             n_workers=pool, n_walkers=8, steps=4,
                             max_blocks=blocks_per_run, poll_interval=0.02,
                             seed=i)
                     for i in range(n_runs)]
            t0 = time.monotonic()
            ids = [svc.submit(s) for s in specs]
            stats = [svc.wait(rid, 600) for rid in ids]
            wall = time.monotonic() - t0
        finally:
            svc.close()
        per_run = [s['n_blocks'] for s in stats]
        rate = sum(per_run) / wall
        if base is None:
            base = rate
        rows.append(dict(
            table='XIV', runs=n_runs, pool=pool, blocks=sum(per_run),
            wall_s=round(wall, 2), blocks_per_s=round(rate, 1),
            vs_single=round(rate / base, 2),
            fairness=round(min(per_run) / max(per_run), 3)))
    return rows


# ---------------------------------------------------------------------------
# Table XV: fused-sweep SEM propagation + mixed-precision footprint
# ---------------------------------------------------------------------------
def table_fused(quick=True):
    """Whole-sweep fusion vs the per-move SEM dispatch loop (DESIGN.md §13).

    Timing rows (one per walker count): the same 60-electron bench system
    propagated one full sweep by

    * ``sem_sweep_s``   — the per-move ``SEMVMCPropagator`` path
      (``method='dense'``): n_e separate AO/MO/Jastrow/update dispatches;
    * ``fused_sweep_s`` — ``sem._fused_cfg`` of the same config
      (``method='fused'``, ``mo_method='dense'``): ONE batched
      proposal/AO/MO/e-n-Jastrow precompute plus one scan per spin block,
      the energy pass still on the dense pipeline.

    Both include the shared post-sweep energy pass.  ``speedup`` =
    sem_sweep_s / fused_sweep_s — same walkers, same box, so the ratio is
    machine-relative and gated by ``tools/bench_gate.py``.
    ``walker_move_us`` is the fused per-walker per-move cost (compare
    Table VIII's ``sem_move_us / walkers``); ``vs_table_viii`` divides the
    committed BENCH_sem.json per-walker sweep time by the fresh fused one
    when that artifact is present (the ISSUE's >= 2x acceptance).

    Memory rows (one per precision): resting footprint of the maintained
    inverses via ``slater.state_bytes`` at ``precision_bytes(p)``;
    ``mem_ratio`` = stored bytes / fp32 bytes (0.5 for bf16/fp16 — must
    never regress upward, gate mode 'max').
    """
    import json as _json
    from pathlib import Path

    from repro.core import sem as sem_mod
    from repro.core import slater
    from repro.core.driver import Population
    from repro.core.sem import SEMVMCPropagator
    from repro.systems.bench import build_bench_wavefunction, \
        make_bench_system

    s = make_bench_system('micro-peptide', n_elec=60, seed=5)
    n_e = s.mol.n_elec
    pop = Population()
    walker_counts = [64] if quick else [64, 256]

    base_walker_sweep_s = None
    bench_sem = Path(__file__).resolve().parents[1] / 'BENCH_sem.json'
    if bench_sem.exists():
        try:
            doc = _json.loads(bench_sem.read_text())
            for row in doc.get('rows', []):
                if row.get('table') == 'VIII' and row.get('n_elec') == n_e:
                    base_walker_sweep_s = (float(row['sem_sweep_s'])
                                           / float(row['walkers']))
        except (ValueError, KeyError):
            pass

    rows = []
    for W in walker_counts:
        cfg, params = build_bench_wavefunction(s, method='dense')
        per = SEMVMCPropagator(cfg, step_size=0.4)
        state = per.init(params, jax.random.PRNGKey(0), W)
        f_per = jax.jit(lambda p, st, k: per.propagate(p, st, k, pop))
        t_per = _timeit(f_per, params, state, jax.random.PRNGKey(1),
                        repeats=5)

        fcfg = sem_mod._fused_cfg(cfg)
        fused = SEMVMCPropagator(fcfg, step_size=0.4)
        fstate = fused.init(params, jax.random.PRNGKey(0), W)
        f_fused = jax.jit(lambda p, st, k: fused.propagate(p, st, k, pop))
        t_fused = _timeit(f_fused, params, fstate, jax.random.PRNGKey(1),
                          repeats=5)

        row = dict(
            table='XV', system=s.name, n_elec=n_e, walkers=W,
            sem_sweep_s=round(t_per, 4), fused_sweep_s=round(t_fused, 4),
            walker_move_us=round(1e6 * t_fused / (n_e * W), 2),
            speedup=round(t_per / t_fused, 2))
        if base_walker_sweep_s is not None:
            row['vs_table_viii'] = round(
                base_walker_sweep_s / (t_fused / W), 2)
        rows.append(row)

    n_up = s.mol.n_up
    n_dn = n_e - n_up
    W_mem = walker_counts[-1]
    fp32_bytes = slater.state_bytes(n_up, n_dn, W_mem, 4)
    for p in slater.PRECISIONS:
        nbytes = slater.state_bytes(n_up, n_dn, W_mem,
                                    slater.precision_bytes(p))
        rows.append(dict(
            table='XV', system=s.name, n_elec=n_e, precision=p,
            walkers=W_mem, state_mb=round(nbytes / 2 ** 20, 3),
            mem_ratio=round(nbytes / fp32_bytes, 3)))
    return rows
